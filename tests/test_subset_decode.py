"""Phase-3 subset decodability (Theorem 6 / eq. 21 mechanics).

The runtime decodes from whatever ``decode_threshold``-sized responder
subset is fastest, so decode must succeed from *every* such subset of
the provisioned pool — not just the primary prefix — for spare counts
0, 1, 2 across PolyDot-CMPC and AGE-CMPC, and must fail loudly below
the threshold.  Runs one protocol execution per scheme and sweeps
subsets of the recorded I(alpha_n); exhaustive when the subset count is
small, a deterministic sample (always including the prefix and the
tail) otherwise.
"""
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic example grid
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import constructions as C
from repro.core import planner
from repro.core import protocol as proto
from repro.core.gf import Field
from repro.core.planner import BlockShapes, make_plan

EXHAUSTIVE_CAP = 300  # max subsets to sweep per (scheme, n_spare) case

SCHEMES = [
    ("polydot", 2, 1, 1),  # small thresholds keep the sweep exhaustive
    ("polydot", 1, 2, 1),
    ("age", 2, 1, 1),
    ("age", 1, 2, 1),
    ("age", 2, 2, 2),
]


def _subsets(n_total: int, thr: int, seed: int):
    """All thr-subsets of range(n_total), or a deterministic sample that
    always includes the primary prefix and the all-spares tail."""
    total = 1
    for i in range(thr):
        total = total * (n_total - i) // (i + 1)
    if total <= EXHAUSTIVE_CAP:
        yield from itertools.combinations(range(n_total), thr)
        return
    rng = np.random.default_rng(seed)
    yield tuple(range(thr))  # prefix fast path
    yield tuple(range(n_total - thr, n_total))  # slowest-tail subset
    for _ in range(EXHAUSTIVE_CAP - 2):
        yield tuple(np.sort(rng.choice(n_total, size=thr, replace=False)))


def _one_execution(method, s, t, z, n_spare, seed):
    field = Field()
    rng = np.random.default_rng(seed)
    sch = C.build_scheme(method, s, t, z)
    shapes = BlockShapes(k=s * 2, ma=t * 2, mb=t * 2, s=s, t=t)
    plan = make_plan(sch, shapes, n_spare=n_spare, seed=seed)
    a = field.random(rng, (shapes.k, shapes.ma))
    b = field.random(rng, (shapes.k, shapes.mb))
    fa = proto.share_a(plan, a, rng)
    fb = proto.share_b(plan, b, rng)
    h = proto.worker_multiply(plan, fa, fb)
    i_evals = proto.degree_reduce(plan, h, rng)
    return plan, i_evals, field.matmul(a.T, b)


@settings(max_examples=10, deadline=None)
@given(
    case=st.sampled_from(SCHEMES),
    n_spare=st.integers(0, 2),
    seed=st.integers(0, 1000),
)
def test_decode_from_every_threshold_subset(case, n_spare, seed):
    method, s, t, z = case
    plan, i_evals, want = _one_execution(method, s, t, z, n_spare, seed)
    thr = plan.decode_threshold
    for ids in _subsets(plan.n_total, thr, seed):
        y = proto.reconstruct(plan, i_evals, worker_ids=np.array(ids))
        assert np.array_equal(y, want), (method, s, t, z, n_spare, ids)


@settings(max_examples=6, deadline=None)
@given(
    case=st.sampled_from(SCHEMES),
    n_spare=st.integers(0, 2),
    short=st.integers(1, 3),
)
def test_below_threshold_fails_loudly(case, n_spare, short):
    method, s, t, z = case
    sch = C.build_scheme(method, s, t, z)
    shapes = BlockShapes(k=s * 2, ma=t * 2, mb=t * 2, s=s, t=t)
    plan = make_plan(sch, shapes, n_spare=n_spare, seed=0)
    n_ids = max(0, plan.decode_threshold - short)
    with pytest.raises(ValueError):
        plan.decode_matrix(np.arange(n_ids))
    with pytest.raises(ValueError):
        proto.reconstruct(
            plan,
            np.zeros((plan.n_total, 2, 2), np.int64),
            worker_ids=np.arange(n_ids),
        )


def test_subset_matrices_cached():
    """Repeated subset decodes hit the plan's subset cache, and the
    prefix fast paths bypass it entirely."""
    planner.subset_cache_clear()
    plan, i_evals, want = _one_execution("age", 2, 2, 2, 2, 7)
    thr = plan.decode_threshold
    ids = np.arange(2, 2 + thr)
    y1 = proto.reconstruct(plan, i_evals, worker_ids=ids)
    info1 = planner.subset_cache_info()
    y2 = proto.reconstruct(plan, i_evals, worker_ids=ids)
    info2 = planner.subset_cache_info()
    assert np.array_equal(y1, want) and np.array_equal(y2, want)
    assert info1["misses"] == 1 and info2["hits"] == info1["hits"] + 1
    # prefix decode does not touch the cache
    proto.reconstruct(plan, i_evals, worker_ids=np.arange(thr))
    assert planner.subset_cache_info()["misses"] == info2["misses"]
    # phase-2 prefix likewise returns the precomputed matrix
    assert plan.phase2_matrix_cached(np.arange(plan.n_workers)) is plan.mix


def test_decode_check_matrix_cached():
    """The master's consistency-check Vandermonde is built once per plan
    (it used to be rebuilt inside every ``run_over_pool`` replay) and
    matches the direct construction."""
    plan, i_evals, want = _one_execution("age", 2, 2, 2, 2, 11)
    v1 = plan.decode_check_matrix()
    v2 = plan.decode_check_matrix()
    assert v1 is v2  # memoized on the plan, not rebuilt
    direct = plan.field.vandermonde(plan.alphas, range(plan.decode_threshold))
    assert np.array_equal(v1, direct)
    assert v1.shape == (plan.n_total, plan.decode_threshold)
    # it predicts every worker's I(alpha_n) from the true coefficients
    thr = plan.decode_threshold
    flat = np.asarray(i_evals).reshape(plan.n_total, -1)
    coeffs = plan.field.matmul(plan.decode_w, flat[:thr])
    assert np.array_equal(plan.field.matmul(v1, coeffs), flat)
