"""Checkpoint manager: atomicity, GC, resume, topology-agnostic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(step):
    return {
        "params": {"w": jnp.full((4, 4), float(step)), "b": jnp.arange(3.0)},
        "opt": {"mu": {"w": jnp.zeros((4, 4)), "b": jnp.zeros(3)}},
        "step": jnp.int32(step),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(5, _state(5), meta={"config": "tiny"})
    step, state = mgr.restore(_state(0))
    assert step == 5
    assert float(state["params"]["w"][0, 0]) == 5.0
    assert int(state["step"]) == 5


def test_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_atomic_tmp_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _state(1))
    # simulate a crash mid-write: stray tmp dir must not be listed
    os.makedirs(tmp_path / "tmp.99")
    assert mgr.all_steps() == [1]
    step, _ = mgr.restore(_state(0))
    assert step == 1


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        mgr.save(s, _state(s))
    step, state = mgr.restore(_state(0), step=2)
    assert step == 2 and float(state["params"]["w"][0, 0]) == 2.0


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1))
    bad = _state(0)
    bad["params"]["w"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state(0))


def test_train_resume_equivalence(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import build_model
    from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule

    rc = dataclasses.replace(
        reduced(get_config("minicpm-2b")), num_layers=2, vocab_size=64, d_model=32,
        num_heads=4, num_kv_heads=4, head_dim=8, d_ff=64,
    )
    model = build_model(rc)
    opt_cfg = AdamWConfig(lr=cosine_schedule(1e-3, 2, 100))
    data = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=4))

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        p2, o2, _ = adamw_update(grads, opt, params, opt_cfg)
        return p2, o2, loss

    # straight run
    p = model.init(jax.random.PRNGKey(0))
    o = adamw_init(p, opt_cfg)
    for i in range(6):
        p, o, _ = step(p, o, data.batch(i))
    straight = p

    # interrupted run
    p = model.init(jax.random.PRNGKey(0))
    o = adamw_init(p, opt_cfg)
    mgr = CheckpointManager(str(tmp_path))
    for i in range(3):
        p, o, _ = step(p, o, data.batch(i))
    mgr.save(3, {"params": p, "opt": o._asdict()})
    _, restored = mgr.restore({"params": p, "opt": o._asdict()})
    p = restored["params"]
    from repro.train.optimizer import AdamWState

    o = AdamWState(**restored["opt"])
    for i in range(3, 6):
        p, o, _ = step(p, o, data.batch(i))  # data resumes by step index
    diff = max(
        float(jnp.abs(a - b).max()) for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(p))
    )
    assert diff < 1e-6
