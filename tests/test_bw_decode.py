"""Berlekamp-Welch error-correcting decode: core algebra and plan glue.

Every case is validated against ground truth: a random polynomial is
evaluated at distinct points, a chosen subset of evaluations is
overwritten with garbage, and BW must recover both the polynomial and
the exact corrupted positions."""
import numpy as np
import pytest

from repro.core import constructions as C
from repro.core import protocol as proto
from repro.core.bw_decode import (
    BWDecodeError,
    bw_decode_evals,
    bw_interpolate,
    bw_system_size,
)
from repro.core.gf import Field
from repro.core.planner import BlockShapes, make_plan

FIELD = Field()


def _poly_points(rng, thr, k, payload=1):
    """Random degree-<thr polynomial + k distinct evaluation points."""
    coeffs = FIELD.random(rng, (thr, payload))
    xs = rng.choice(FIELD.p - 1, size=k, replace=False) + 1
    v = FIELD.vandermonde(xs, range(thr))
    return coeffs, xs, FIELD.matmul(v, coeffs)


def _corrupt(rng, ys, rows):
    out = ys.copy()
    for r in rows:
        while True:
            g = FIELD.random(rng, out[r].shape)
            if not np.array_equal(g, ys[r]):
                break
        out[r] = g
    return out


# ----------------------------------------------------------------------
# field helpers the decoder is built on
# ----------------------------------------------------------------------
def test_solve_any_rank_deficient():
    """Singular-but-consistent systems yield a valid particular solution
    (free variables pinned to 0); inconsistent ones raise."""
    rng = np.random.default_rng(0)
    a = FIELD.random(rng, (4, 3))
    a = np.concatenate([a, a[:1]], axis=0)  # duplicate row: rank <= 3
    x_true = FIELD.random(rng, (3,))
    b = FIELD.matmul(a, x_true[:, None])[:, 0]
    x = FIELD.solve_any(a, b)
    assert np.array_equal(FIELD.matmul(a, x[:, None])[:, 0], b)
    bad = b.copy()
    bad[-1] = (bad[-1] + 1) % FIELD.p
    with pytest.raises(ValueError, match="inconsistent"):
        FIELD.solve_any(a, bad)


def test_poly_divmod_and_eval():
    rng = np.random.default_rng(1)
    den = np.concatenate([FIELD.random(rng, (2,)), np.ones(1, np.int64)])
    quo_true = FIELD.random(rng, (4,))
    num = np.zeros(den.size + quo_true.size - 1, np.int64)
    for i, d in enumerate(den):
        num[i : i + quo_true.size] = (num[i : i + quo_true.size]
                                      + d * quo_true) % FIELD.p
    quo, rem = FIELD.poly_divmod(num, den)
    assert np.array_equal(quo, quo_true)
    assert not rem.size or not np.any(rem)
    xs = np.arange(1, 8)
    v = FIELD.vandermonde(xs, range(num.size))
    assert np.array_equal(
        FIELD.poly_eval(num, xs), FIELD.matmul(v, num[:, None])[:, 0]
    )


# ----------------------------------------------------------------------
# bw_interpolate: the standalone error-correcting interpolation
# ----------------------------------------------------------------------
@pytest.mark.parametrize("e", [0, 1, 2, 3])
def test_recovers_with_e_errors(e):
    rng = np.random.default_rng(10 + e)
    thr = 6
    k = bw_system_size(thr, e)
    coeffs, xs, ys = _poly_points(rng, thr, k)
    bad = rng.choice(k, size=e, replace=False)
    got, err = bw_interpolate(
        FIELD, xs, _corrupt(rng, ys, bad)[:, 0], thr, e, rng=rng
    )
    assert np.array_equal(got, coeffs[:, 0])
    assert np.array_equal(np.sort(err), np.sort(bad))


@pytest.mark.parametrize("payload", [1, 5])
def test_vector_payload_shares_error_pattern(payload):
    """A corrupt row corrupts its whole payload; one locator pass on the
    random combination must find it and the full payload decode."""
    rng = np.random.default_rng(2)
    thr, e = 5, 2
    k = bw_system_size(thr, e) + 2  # slack rows beyond the minimum
    coeffs, xs, ys = _poly_points(rng, thr, k, payload)
    bad = [0, 4]
    got, err = bw_interpolate(FIELD, xs, _corrupt(rng, ys, bad), thr, e, rng=rng)
    assert np.array_equal(got, coeffs)  # [thr, payload] in, same shape out
    assert np.array_equal(err, np.array(bad))


def test_fewer_errors_than_budget():
    """Actual errors < e leaves the system singular; the decode must
    still succeed and must not flag clean rows."""
    rng = np.random.default_rng(3)
    thr, e = 6, 3
    coeffs, xs, ys = _poly_points(rng, thr, bw_system_size(thr, e))
    got, err = bw_interpolate(
        FIELD, xs, _corrupt(rng, ys, [2])[:, 0], thr, e, rng=rng
    )
    assert np.array_equal(got, coeffs[:, 0])
    assert err.tolist() == [2]
    got, err = bw_interpolate(FIELD, xs, ys[:, 0], thr, e, rng=rng)  # 0 errors
    assert np.array_equal(got, coeffs[:, 0])
    assert err.size == 0


def test_over_budget_raises():
    rng = np.random.default_rng(4)
    thr, e = 6, 2
    _, xs, ys = _poly_points(rng, thr, bw_system_size(thr, e))
    ys_bad = _corrupt(rng, ys, [0, 1, 2])  # e + 1 errors
    with pytest.raises(BWDecodeError):
        bw_interpolate(FIELD, xs, ys_bad, thr, e, rng=rng)


def test_input_validation():
    rng = np.random.default_rng(5)
    _, xs, ys = _poly_points(rng, 4, 8)
    with pytest.raises(ValueError, match="thr \\+ 2e"):
        bw_interpolate(FIELD, xs, ys, 4, 3, rng=rng)  # k < thr + 2e
    xs_dup = xs.copy()
    xs_dup[1] = xs_dup[0]
    with pytest.raises(ValueError, match="distinct"):
        bw_interpolate(FIELD, xs_dup, ys, 4, 2, rng=rng)
    with pytest.raises(ValueError, match=">= 0"):
        bw_interpolate(FIELD, xs, ys, 4, -1, rng=rng)


# ----------------------------------------------------------------------
# bw_decode_evals: plan-aware decode of Phase-3 responses
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def plan_setup():
    field = Field()
    sch = C.build_scheme("age", 2, 2, 2)
    shapes = BlockShapes(k=8, ma=8, mb=4, s=2, t=2)
    plan = make_plan(sch, shapes, n_spare=6, seed=1)
    rng = np.random.default_rng(0)
    a = field.random(rng, (8, 8))
    b = field.random(rng, (8, 4))
    return plan, a, b, field.matmul(a.T, b)


def _phase3_rows(plan, a, b, seed=0):
    rng = np.random.default_rng(seed)
    fa = proto.share_a(plan, a, rng)
    fb = proto.share_b(plan, b, rng)
    h = proto.worker_multiply(plan, fa, fb)
    i_all = np.array(proto.degree_reduce(plan, h, rng))
    return i_all.reshape(plan.n_total, -1), rng


@pytest.mark.parametrize("e", [0, 1, 2, 3])
def test_plan_decode_corrects_and_names(plan_setup, e):
    plan, a, b, want = plan_setup
    flat, rng = _phase3_rows(plan, a, b, seed=20 + e)
    ids = np.arange(bw_system_size(plan.decode_threshold, e))
    bad = ids[:e]
    for w in bad:
        flat[w] = FIELD.random(rng, flat[w].shape)
    coeffs, corrected = bw_decode_evals(plan, flat, ids, e, rng=rng)
    assert np.array_equal(proto.assemble_y(plan, coeffs), want)
    assert np.array_equal(corrected, np.sort(bad))


def test_plan_decode_over_budget(plan_setup):
    plan, a, b, _ = plan_setup
    flat, rng = _phase3_rows(plan, a, b, seed=30)
    e = 1
    ids = np.arange(bw_system_size(plan.decode_threshold, e))
    for w in ids[:2]:  # e + 1 corrupt
        flat[w] = FIELD.random(rng, flat[w].shape)
    with pytest.raises(BWDecodeError):
        bw_decode_evals(plan, flat, ids, e, rng=rng)


def test_bw_matrices_cached(plan_setup):
    plan, _, _, _ = plan_setup
    ids = np.arange(bw_system_size(plan.decode_threshold, 2))
    m1 = plan.bw_decode_matrices(ids, 2)
    m2 = plan.bw_decode_matrices(ids, 2)
    assert m1 is m2  # same subset + budget -> cache hit
    m3 = plan.bw_decode_matrices(ids, 1)
    assert m3.shape[1] == plan.decode_threshold + 1  # budget keys differ
    assert m1.shape == (ids.size, plan.decode_threshold + 2)
    with pytest.raises(ValueError, match=">= 0"):
        plan.bw_decode_matrices(ids, -1)


def test_reconstruct_corrected_matches_reconstruct(plan_setup):
    """protocol.reconstruct_corrected on a clean pool == reconstruct."""
    plan, a, b, want = plan_setup
    flat, rng = _phase3_rows(plan, a, b, seed=40)
    ids = np.arange(bw_system_size(plan.decode_threshold, 2))
    y, corrected = proto.reconstruct_corrected(plan, flat, ids, 2, rng=rng)
    assert np.array_equal(y, want)
    assert corrected.size == 0
